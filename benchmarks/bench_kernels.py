"""Kernel-level push/pull wall-clock suite — the ``kernel_*`` rows.

The first *wall-clock* (not counter-only) trajectory in BENCH: for every
(direction × combine × graph family × batch width) cell, time the jnp
primitive (``pull_relax_ell`` / ``push_relax``) against the Pallas
kernel (``ell_spmv_pallas`` / ``coo_push_pallas``) at the autotuned
configuration (block sizes + push reduce strategy; push runs on a
prebuilt phase-1 bin plan, matching the backend's per-graph cache),
check they agree, and emit one schema-validated ``kernel_cell`` row
(``benchmarks/schema.json``). Every row also reports its analytic
roofline anchors — ``bytes_moved``, ``flops``, ``pct_roofline`` (via
``repro.roofline.analysis.kernel_roofline``) — so the trajectory tracks
distance-to-hardware, not just distance-to-jnp.

    PYTHONPATH=src python -m benchmarks.run --only kernels \
        --json BENCH_kernels.json

``kernel_pullf_*`` rows cover the frontier-restricted pull
(``ell_pull_frontier_pallas``) on BFS-shaped touched sets at ≤10%
density, against both the jnp masked pull (``us_jnp``) and the
full-scan kernel + mask (``us_full_kernel``) — the committed run must
show the frontier kernel beating the full scan on at least one sparse
cell, which is the wall-clock grounding for ``PallasBackend`` pricing
restricted pulls cheaper than ``(m, n)``.

``--smoke`` shrinks to the RMAT family × sum × both directions (CI
asserts the rows exist and validate — interpreter wall-clock is only
meaningful relatively, and only the committed full run claims the
pull-side win). The model kernels (flash attention, CIN) keep a small
sanity row each under the ``aux_`` prefix.
"""

from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .common import emit, timeit


def _graphs(smoke: bool):
    from repro.graphs import erdos_renyi, kronecker
    if smoke:
        return {"rmat": kronecker(7, edge_factor=6, seed=7,
                                  weighted=True)}
    return {
        "rmat": kronecker(10, edge_factor=8, seed=7, weighted=True),
        "uniform": erdos_renyi(1024, 8.0, seed=5, weighted=True),
    }


def _payload(g, batch: int, dtype):
    shape = (g.n,) if batch == 1 else (g.n, batch)
    key = jax.random.PRNGKey(3)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.random.normal(key, shape, dtype)
    return jax.random.randint(key, shape, -100, 100).astype(dtype)


@functools.partial(jax.jit, static_argnames=("combine",))
def _jnp_pull(g, x, combine):
    from repro.core.primitives import pull_relax_ell
    return pull_relax_ell(g, x, combine=combine)[0]


@functools.partial(jax.jit, static_argnames=("combine",))
def _jnp_push(g, x, active, combine):
    from repro.core.primitives import push_relax
    return push_relax(g, x, active, combine=combine)[0]


@functools.partial(jax.jit, static_argnames=("combine",))
def _jnp_pull_masked(g, x, touched, combine):
    from repro.core.primitives import mask_untouched, pull_relax_ell
    out = pull_relax_ell(g, x, combine=combine)[0]
    return mask_untouched(out, touched, combine)


@functools.partial(jax.jit,
                   static_argnames=("combine", "rows_n", "block_r"))
def _pallas_pullf(xp, ell_idx, ell_w, touched, combine, rows_n, block_r):
    # compaction + frontier kernel + identity scatter under one jit —
    # how the engine's traced pull path runs it (eager nonzero dispatch
    # would otherwise dominate the measurement)
    from repro.kernels.ell_pull_frontier import (ell_pull_frontier_full,
                                                 frontier_rows)
    rows = frontier_rows(touched, rows_n)
    return ell_pull_frontier_full(xp, ell_idx, ell_w, rows,
                                  combine=combine, msg="copy",
                                  block_r=block_r)


def _bfs_touched_sets(g, layout, max_density=0.10, max_levels=4, keep=2):
    """BFS-shaped touched sets: each BFS level's frontier, expanded to
    the destinations its pull step would touch (N_out of the frontier —
    what the engine's ``touched_fn`` hands the backend). Keeps the
    first ``keep`` levels at ≤ ``max_density`` — the sparse-frontier
    regime where restricting the scan is supposed to pay."""
    from repro import api
    from repro.kernels.layout import touched_out_mask
    dist = np.asarray(api.solve(g, "bfs", root=0).state["dist"])
    out = []
    for lv in range(max_levels):
        frontier = jnp.asarray(dist == lv)
        if not bool(frontier.any()):
            break
        touched = touched_out_mask(layout, frontier)
        cnt = int(jnp.sum(touched))
        if cnt and cnt / g.n <= max_density:
            out.append((lv, touched, cnt))
        if len(out) == keep:
            break
    return out


def _agree(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return bool(np.allclose(a, b, rtol=1e-5, atol=1e-5,
                                equal_nan=True))
    return bool(np.array_equal(a, b))


def _cell(direction, combine, gname, g, batch, extra):
    return dict({
        "direction": direction, "combine": combine, "graph": gname,
        "n": int(g.n), "m": int(g.m), "d_ell": int(g.d_ell),
        "batch": int(batch), "dtype": "float32", "msg": "copy",
    }, **extra)


def run():
    from repro.graphs.structure import pad_values
    from repro.kernels.coo_push import build_push_plan, coo_push_pallas
    from repro.kernels.ell_spmv import ell_spmv_pallas
    from repro.kernels.tune import tune_pull, tune_push
    from repro.roofline.analysis import kernel_roofline

    combines = ("sum",) if common.SMOKE else ("sum", "min")
    batches = (1, 8)
    # interpret-mode medians at 2-3 iters are noisy enough to flip the
    # CI regression gate; 7 stabilizes them at negligible suite cost
    iters = 7

    for gname, g in _graphs(common.SMOKE).items():
        for combine in combines:
            for batch in batches:
                x = _payload(g, batch, jnp.float32)
                # ---- pull: jnp ELL gather vs Pallas ell_spmv --------
                us_jnp = timeit(lambda: _jnp_pull(g, x, combine),
                                iters=iters)
                block_n = tune_pull(g.n, g.d_ell, batch, x.dtype,
                                    combine, "copy")
                xp = pad_values(x)
                pallas_pull = lambda: ell_spmv_pallas(  # noqa: E731
                    xp, g.ell_idx, g.ell_w, combine=combine, msg="copy",
                    block_n=block_n)
                us_pal = timeit(pallas_pull, iters=iters)
                roof = kernel_roofline(
                    "pull", n=g.n, d_ell=g.d_ell, batch=batch,
                    itemsize=x.dtype.itemsize, measured_us=us_pal)
                cell = _cell("pull", combine, gname, g, batch, {
                    "block_n": int(block_n),
                    "us_jnp": round(us_jnp, 1),
                    "us_pallas": round(us_pal, 1),
                    "speedup": round(us_jnp / max(us_pal, 1e-9), 3),
                    "match": _agree(_jnp_pull(g, x, combine),
                                    pallas_pull()),
                    "bytes_moved": roof["bytes_moved"],
                    "flops": roof["flops"],
                    "pct_roofline": roof["pct_roofline"],
                })
                emit(f"kernel_pull_{combine}_{gname}_b{batch}", us_pal,
                     json.dumps(cell))

                # ---- push: jnp segment scatter vs Pallas coo_push ---
                active = jnp.ones((g.n,), bool)
                us_jnp = timeit(lambda: _jnp_push(g, x, active, combine),
                                iters=iters)
                block_e, pbn, strategy = tune_push(
                    g.n, g.m, batch, x.dtype, combine, "copy")
                # phase-1 bin layout: built once per graph and cached on
                # the backend in production, so timed separately here
                plan = build_push_plan(g.coo_src, g.coo_dst, g.coo_w,
                                       g.n, pbn, align=block_e)
                pallas_push = lambda: coo_push_pallas(  # noqa: E731
                    x, active, g.coo_src, g.coo_dst, g.coo_w, g.n,
                    combine=combine, msg="copy", block_e=block_e,
                    block_n=pbn, plan=plan, strategy=strategy)
                us_pal = timeit(pallas_push, iters=iters)
                roof = kernel_roofline(
                    "push", n=g.n, batch=batch,
                    itemsize=x.dtype.itemsize, nb=plan.nb, cap=plan.cap,
                    bin_n=plan.bin_n, measured_us=us_pal)
                cell = _cell("push", combine, gname, g, batch, {
                    "block_e": int(block_e), "block_n": int(pbn),
                    "strategy": strategy, "bins": int(plan.nb),
                    "us_jnp": round(us_jnp, 1),
                    "us_pallas": round(us_pal, 1),
                    "speedup": round(us_jnp / max(us_pal, 1e-9), 3),
                    "match": _agree(_jnp_push(g, x, active, combine),
                                    pallas_push()),
                    "bytes_moved": roof["bytes_moved"],
                    "flops": roof["flops"],
                    "pct_roofline": roof["pct_roofline"],
                })
                emit(f"kernel_push_{combine}_{gname}_b{batch}", us_pal,
                     json.dumps(cell))

    # ---- frontier pull: touched-row gather vs full scan + mask ------
    # kernel_pullf_* rows time the PR 8 dispatch against both honest
    # baselines on the SAME touched set: the jnp full pull + mask
    # (us_jnp) and the full-scan Pallas kernel + mask (us_full_kernel,
    # the pre-frontier kernel path). us_pallas includes the frontier
    # compaction and identity scatter, so the speedup is end to end.
    from repro.core.primitives import mask_untouched
    from repro.kernels.layout import build_dual_ell
    from repro.kernels.tune import tune_pull_frontier

    for gname, g in _graphs(common.SMOKE).items():
        layout = build_dual_ell(g)
        fronts = _bfs_touched_sets(g, layout)
        xp_cache = {}
        for combine in combines:
            for batch in batches:
                x = xp_cache.setdefault(batch, _payload(g, batch,
                                                        jnp.float32))
                xp = pad_values(x)
                block_n = tune_pull(g.n, g.d_ell, batch, x.dtype,
                                    combine, "copy")
                for lv, touched, cnt in fronts:
                    # same pow-of-two row-capacity bucketing as the
                    # backend's concrete dispatch
                    rows_n = max(8, 1 << (cnt - 1).bit_length())
                    us_jnp = timeit(
                        lambda: _jnp_pull_masked(g, x, touched, combine),
                        iters=iters)
                    full_kernel = lambda: mask_untouched(  # noqa: E731
                        ell_spmv_pallas(xp, g.ell_idx, g.ell_w,
                                        combine=combine, msg="copy",
                                        block_n=block_n),
                        touched, combine)
                    us_full = timeit(full_kernel, iters=iters)
                    block_r = tune_pull_frontier(
                        g.n, g.d_ell, rows_n, batch, x.dtype, combine,
                        "copy")
                    pallas_f = lambda: _pallas_pullf(  # noqa: E731
                        xp, layout.in_idx, layout.in_w, touched,
                        combine, rows_n, block_r)
                    us_pal = timeit(pallas_f, iters=iters)
                    roof = kernel_roofline(
                        "pullf", n=rows_n, d_ell=g.d_ell, batch=batch,
                        itemsize=x.dtype.itemsize, measured_us=us_pal)
                    cell = _cell("pullf", combine, gname, g, batch, {
                        "block_n": int(block_r),
                        "rows": int(rows_n),
                        "density": round(cnt / g.n, 4),
                        "us_jnp": round(us_jnp, 1),
                        "us_full_kernel": round(us_full, 1),
                        "us_pallas": round(us_pal, 1),
                        "speedup": round(us_full / max(us_pal, 1e-9), 3),
                        "match": _agree(
                            _jnp_pull_masked(g, x, touched, combine),
                            pallas_f()),
                        "bytes_moved": roof["bytes_moved"],
                        "flops": roof["flops"],
                        "pct_roofline": roof["pct_roofline"],
                    })
                    emit(f"kernel_pullf_{combine}_{gname}_b{batch}_L{lv}",
                         us_pal, json.dumps(cell))

    # ---- model-kernel sanity rows (aux_: not kernel_cell shaped) ----
    from repro.kernels import cin_layer, flash_attention
    from repro.kernels import ref as R
    key = jax.random.PRNGKey(1)
    B, T, H, d = 1, 128 if common.SMOKE else 256, 4, 64
    q = jax.random.normal(key, (B, T, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, d))
    want = R.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3)
                                 ).transpose(0, 2, 1, 3)
    ok = bool(jnp.allclose(flash_attention(q, k, v), want, atol=1e-3))
    t = timeit(lambda: flash_attention(q, k, v), iters=2)
    emit("aux_flash_attention", t, f"allclose={ok};T={T}")

    xk = jax.random.normal(key, (64, 50, 10), jnp.float32)
    x0 = jax.random.normal(jax.random.fold_in(key, 3), (64, 20, 10))
    w = jax.random.normal(jax.random.fold_in(key, 4), (50, 50, 20)) * 0.01
    ok = bool(jnp.allclose(cin_layer(xk, x0, w), R.cin_layer_ref(xk, x0, w),
                           rtol=1e-3, atol=1e-3))
    t = timeit(lambda: cin_layer(xk, x0, w), iters=2)
    emit("aux_cin", t, f"allclose={ok};B=64;H=50")


if __name__ == "__main__":
    run()
