"""§Roofline table from the dry-run artifact (dryrun_results.json).

Prints per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line lever.
"""

from __future__ import annotations

import json
import os

from repro.configs import ARCH_FAMILY, full_config, shape_table
from repro.roofline.analysis import HW, model_flops

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def _lm_params(cfg, active_only=False):
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = D * (H * hd) + 2 * D * (Hk * hd) + (H * hd) * D
    if cfg.moe is not None:
        e = cfg.moe
        per_exp = 3 * D * e.d_ff_expert
        routed = per_exp * (e.top_k if active_only else e.n_experts)
        ffn = routed + per_exp * e.n_shared + D * e.n_experts
    else:
        ffn = 3 * D * F
    return L * (attn + ffn) + 2 * V * D


def _tokens(arch, shape):
    p = shape_table("lm")[shape].params
    if shape in ("decode_32k", "long_500k"):
        return p["global_batch"]                    # one new token per seq
    return p["global_batch"] * p["seq_len"]


def useful_flops(arch: str, shape: str, n_dev: int) -> float | None:
    if ARCH_FAMILY[arch] != "lm":
        return None
    cfg = full_config(arch)
    kind = "train" if shape == "train_4k" else "serve"
    n = _lm_params(cfg, active_only=True)
    return model_flops(kind, n_active_params=n,
                       tokens=_tokens(arch, shape)) / n_dev


def lever(dominant: str, cell: str) -> str:
    if dominant == "collective":
        return ("reshape TP->DP/ZeRO or sequence-shard activations; "
                "overlap the exchange")
    if dominant == "memory":
        return ("raise arithmetic intensity: fuse/bigger tiles, bf16 "
                "payloads, cut remat rereads")
    return "already MXU-bound: tighten block shapes to keep MXU hot"


def run(path: str = RESULTS):
    with open(path) as f:
        data = json.load(f)
    print(f"{'cell':42s} {'mesh':8s} {'comp_s':>9s} {'mem_s':>9s} "
          f"{'coll_s':>9s} {'dominant':>10s} {'useful/HLO':>10s}")
    for r in sorted(data["results"], key=lambda r: (r["cell"], r["mesh"])):
        rf = r["roofline"]
        arch, shape = r["cell"].split("@")
        uf = useful_flops(arch, shape, r["n_devices"])
        hlo_flops = (r["cost"]["flops"] or 0) * rf.get("loop_factor", 1)
        ratio = uf / hlo_flops if uf and hlo_flops else None
        print(f"{r['cell']:42s} {r['mesh']:8s} {rf['compute_s']:9.2e} "
              f"{rf['memory_s']:9.2e} {rf['collective_s']:9.2e} "
              f"{rf['dominant']:>10s} "
              f"{('%.2f' % ratio) if ratio else '-':>10s}")
    doms = {}
    for r in data["results"]:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    emit("roofline_cells", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(doms.items())))


if __name__ == "__main__":
    run()
