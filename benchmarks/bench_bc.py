"""Fig 5: Betweenness Centrality push vs pull (pull = Madduri successor
trick removes float locks in both Brandes phases)."""

from __future__ import annotations

from repro.core.algorithms import betweenness_centrality

from .common import emit, graph, timeit


def run():
    g = graph("orc", scale=1.0 / 1024)
    for k in (4, 16):
        t_push = timeit(
            lambda: betweenness_centrality(g, "push", num_sources=k),
            iters=2)
        t_pull = timeit(
            lambda: betweenness_centrality(g, "pull", num_sources=k),
            iters=2)
        emit(f"bc_push_orc_k{k}", t_push, "")
        emit(f"bc_pull_orc_k{k}", t_pull,
             f"pull/push={t_pull/t_push:.2f}")
    locks_push = betweenness_centrality(g, "push", num_sources=4).cost
    locks_pull = betweenness_centrality(g, "pull", num_sources=4).cost
    emit("bc_locks", 0.0,
         f"push={int(locks_push.locks)};pull={int(locks_pull.locks)}")


if __name__ == "__main__":
    run()
