"""Fig 3: distributed-memory exchange schedules.

Reproduces the paper's DM finding *structurally*: the combined-alltoall
("MP") push moves O(n) bytes/device; RMA-pull all_gathers O(n); RMA-push
(per-edge accumulate) moves O(cut·8) unaggregated bytes — the paper
measured it >10x slower for PR. We report analytic bytes/device for a P
sweep (from the PA split) + measured wall-clock on 8 fake host devices
(subprocess — the main bench process keeps 1 device)."""

from __future__ import annotations

import os
import subprocess
import sys

from repro.graphs import partition_1d, pa_split

from .common import emit, graph

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time, numpy as np
import jax, jax.numpy as jnp
from repro.graphs import standin, partition_1d, pa_split
from repro.dist.collectives import push_exchange, pull_exchange
mesh = jax.make_mesh((8, 1), ("data", "model"))
g = standin("orc", scale=1.0/256)
part = partition_1d(g.n, 8)
local, remote, stats = pa_split(g, part)
vals = jnp.ones((part.n_padded,), jnp.float32)
for name, fn in (("push", push_exchange), ("pull", pull_exchange)):
    out, nbytes = fn(mesh, part, remote, vals)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        out, _ = fn(mesh, part, remote, vals)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / 3 * 1e6
    print(f"{name},{dt:.1f},{nbytes}")
"""


def run():
    g = graph("orc")
    for P in (4, 16, 64, 256):
        part = partition_1d(g.n, P)
        _, remote, stats = pa_split(g, part)
        mp_bytes = part.n_padded * 4
        pull_bytes = part.n_padded * 4 * (P - 1) // P
        rma_push_bytes = stats["cut_edges"] * 8 // P
        emit(f"dm_bytes_P{P}", 0.0,
             f"cut={stats['cut_edges']};mp_push={mp_bytes};"
             f"rma_pull={pull_bytes};rma_push={rma_push_bytes}")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUB], capture_output=True,
                       text=True, timeout=600, env=env, cwd="/root/repo")
    for line in r.stdout.splitlines():
        if "," in line:
            name, dt, nbytes = line.split(",")
            emit(f"dm_exchange_{name}_8dev", float(dt), f"bytes={nbytes}")
    if r.returncode != 0:
        print(r.stderr[-1500:])


if __name__ == "__main__":
    run()
