"""Table 6a: acceleration strategies — PR+PA win on dense graphs, loss on
sparse (the paper's surprising result), plus BFS direction-switch ratio."""

from __future__ import annotations

from repro.core.algorithms import bfs, pagerank, pagerank_pa
from repro.core.direction import Direction, Fixed, GenericSwitch

from .common import emit, graph, timeit


def run():
    # PR + PA: combining-write reduction by graph density
    for gname in ("orc", "rca"):
        g = graph(gname)
        base = pagerank(g, 5, direction="push")
        pa = pagerank_pa(g, 16, 5)
        emit(f"pa_locks_{gname}", 0.0,
             f"push={int(base.cost.locks)};pa={int(pa.cost.locks)};"
             f"ratio={int(pa.cost.locks)/max(1,int(base.cost.locks)):.3f}")

    # BFS direction optimization: edge-examination ratio (Beamer ~2.4x)
    g = graph("orc")
    push = bfs(g, 0, Fixed(Direction.PUSH))
    pull = bfs(g, 0, Fixed(Direction.PULL))
    auto = bfs(g, 0, GenericSwitch())
    emit("gs_bfs_reads", 0.0,
         f"push={int(push.cost.reads)};pull={int(pull.cost.reads)};"
         f"auto={int(auto.cost.reads)};"
         f"speedup_vs_pull={int(pull.cost.reads)/max(1,int(auto.cost.reads)):.2f}x")
    t_auto = timeit(lambda: bfs(g, 0, GenericSwitch()), iters=2)
    t_pull = timeit(lambda: bfs(g, 0, Fixed(Direction.PULL)), iters=2)
    emit("gs_bfs_time", t_auto, f"pull_time={t_pull:.0f}us")

    # speed of convergence (paper §1): data-driven residual PR reaches
    # the fixpoint with a fraction of the synchronous edge work
    from repro.core.algorithms import pagerank_delta
    g2 = graph("pok")
    dd = pagerank_delta(g2, tol=1e-8, direction="push")
    sync = pagerank(g2, 120, direction="push")
    emit("pr_delta_work", 0.0,
         f"dd_reads={int(dd.cost.reads)};sync_reads={int(sync.cost.reads)};"
         f"saving={int(sync.cost.reads)/max(1,int(dd.cost.reads)):.2f}x;"
         f"rounds={int(dd.rounds)}")


if __name__ == "__main__":
    run()
