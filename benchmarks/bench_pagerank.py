"""Table 3 / Table 4 / Table 6a: PageRank time per iteration,
push vs pull vs push+PA, across the five stand-in graphs."""

from __future__ import annotations

from repro.core.algorithms import pagerank
from repro.core.algorithms.pagerank import pagerank_pa_prepare

from .common import emit, graph, timeit

GRAPHS = ("orc", "pok", "ljn", "am", "rca")
ITERS = 5


def run():
    results = {}
    for gname in GRAPHS:
        g = graph(gname)
        t_push = timeit(lambda: pagerank(g, ITERS, direction="push")) / ITERS
        t_pull = timeit(lambda: pagerank(g, ITERS, direction="pull")) / ITERS
        t_ell = timeit(lambda: pagerank(g, ITERS, direction="pull",
                                        use_ell=True)) / ITERS
        pa_run, _ = pagerank_pa_prepare(g, 16, ITERS)
        t_pa = timeit(pa_run) / ITERS
        results[gname] = (t_push, t_pull, t_ell, t_pa)
        emit(f"pagerank_push_{gname}", t_push, f"n={g.n},m={g.m}")
        emit(f"pagerank_pull_{gname}", t_pull,
             f"pull/push={t_pull/t_push:.2f}")
        emit(f"pagerank_pull_ell_{gname}", t_ell, "")
        emit(f"pagerank_pushPA_{gname}", t_pa,
             f"pa/push={t_pa/t_push:.2f}")
    return results


if __name__ == "__main__":
    run()
