"""Table 3 (right): Triangle Counting total time, push vs pull.

Paper: pull wins (~2-4%) because push pays FAA atomics; our counters carry
that; wall-clock here reflects the dense-combine formulation."""

from __future__ import annotations

from repro.core.algorithms import triangle_count

from .common import emit, graph, timeit

GRAPHS = ("pok", "am", "rca")   # TC is O(m*d^2): small sparse stand-ins


def run():
    out = {}
    for gname in GRAPHS:
        g = graph(gname, scale=1.0 / 4096)
        t_push = timeit(lambda: triangle_count(g, "push"), iters=2)
        t_pull = timeit(lambda: triangle_count(g, "pull"), iters=2)
        total = int(triangle_count(g, "pull").total)
        out[gname] = (t_push, t_pull)
        emit(f"tc_push_{gname}", t_push, f"triangles={total}")
        emit(f"tc_pull_{gname}", t_pull,
             f"pull/push={t_pull/t_push:.2f}")
    return out


if __name__ == "__main__":
    run()
