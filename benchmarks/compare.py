"""Diff two BENCH_*.json reports — the regression gate for the BENCH
trajectory.

    PYTHONPATH=src python -m benchmarks.compare OLD.json NEW.json \
        [--fail-below 0.8] [--metric us_per_call]

Rows are matched by ``name`` (the stable per-cell id every suite
emits). For each shared row the tool prints ``speedup = old/new`` on
the chosen metric (>1 = NEW is faster/cheaper), plus rows only one
report has. ``--fail-below RATIO`` exits 1 when any shared cell's
speedup drops under RATIO — e.g. ``--fail-below 0.8`` tolerates a 20%
per-cell regression before failing the build.

``--metric`` picks what to compare: ``us_per_call`` (default, wall
clock) or any numeric key of the row's derived payload, dotted for
nesting (``weighted_total``, ``counters.reads``). Cells missing the
metric are listed and skipped, never silently dropped.

Metrics where *larger is better* (a kernel row's ``speedup`` over the
jnp baseline, a throughput) need the ratio flipped: pass
``--higher-is-better`` and the per-cell ratio becomes ``new/old``
(still >1 = NEW wins), so ``--fail-below`` keeps its meaning — e.g.
``--metric speedup --higher-is-better --fail-below 0.25`` fails when
any cell's kernel speedup collapses to under a quarter of the
committed trajectory's.
"""

from __future__ import annotations

import argparse
import json
import sys

__all__ = ["compare_reports", "main"]


def _metric_value(row: dict, metric: str):
    """The metric for one report row: ``us_per_call`` from the row
    itself, anything else resolved (dotted) inside ``derived``."""
    if metric == "us_per_call":
        v = row.get("us_per_call")
        return v if isinstance(v, (int, float)) else None
    node = row.get("derived")
    for part in metric.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node if isinstance(node, (int, float)) else None


def compare_reports(old: dict, new: dict,
                    metric: str = "us_per_call",
                    higher_is_better: bool = False) -> dict:
    """Structured diff of two reports: per-cell speedups on ``metric``
    (``old/new`` for cost-like metrics, ``new/old`` when
    ``higher_is_better`` — either way >1 means NEW wins), plus the rows
    only one side has or that lack the metric."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    cells, skipped = [], []
    for name in sorted(old_rows.keys() & new_rows.keys()):
        ov = _metric_value(old_rows[name], metric)
        nv = _metric_value(new_rows[name], metric)
        if ov is None or nv is None:
            skipped.append(name)
            continue
        num, den = (nv, ov) if higher_is_better else (ov, nv)
        # both zero = unchanged; a zero denominator otherwise means the
        # winning side became free — treat as a large win, never a crash
        speedup = 1.0 if ov == nv else (num / den if den else float("inf"))
        cells.append({"name": name, "old": ov, "new": nv,
                      "speedup": speedup})
    return {"metric": metric, "cells": cells, "skipped": skipped,
            "higher_is_better": higher_is_better,
            "only_old": sorted(old_rows.keys() - new_rows.keys()),
            "only_new": sorted(new_rows.keys() - old_rows.keys())}


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def render_diff(diff: dict, threshold: float | None = None) -> str:
    ratio = "new/old" if diff.get("higher_is_better") else "old/new"
    lines = [f"# BENCH diff · metric `{diff['metric']}` "
             f"(speedup = {ratio}, >1 means NEW wins)", ""]
    cells = sorted(diff["cells"], key=lambda c: c["speedup"])
    if cells:
        lines += ["| cell | old | new | speedup | |", "|---|--:|--:|--:|---|"]
        for c in cells:
            flag = ""
            if threshold is not None and c["speedup"] < threshold:
                flag = f"REGRESSION < {threshold}"
            lines.append(f"| {c['name']} | {_fmt(c['old'])} "
                         f"| {_fmt(c['new'])} | {c['speedup']:.2f} "
                         f"| {flag} |")
        lines.append("")
        worst = cells[0]
        best = cells[-1]
        lines.append(f"{len(cells)} shared cells · worst "
                     f"{worst['speedup']:.2f} ({worst['name']}) · best "
                     f"{best['speedup']:.2f} ({best['name']}).")
        lines.append("")
    for key, label in (("skipped", "missing the metric"),
                       ("only_old", "only in OLD"),
                       ("only_new", "only in NEW")):
        if diff[key]:
            lines.append(f"- {len(diff[key])} cell(s) {label}: "
                         + ", ".join(f"`{n}`" for n in diff[key][:8])
                         + ("…" if len(diff[key]) > 8 else ""))
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="Diff two BENCH_*.json reports cell by cell.")
    ap.add_argument("old", help="baseline report (e.g. the committed "
                                "BENCH_pushpull.json)")
    ap.add_argument("new", help="candidate report to judge")
    ap.add_argument("--metric", default="us_per_call",
                    help="us_per_call (default) or a dotted derived key "
                         "(weighted_total, counters.reads, ...)")
    ap.add_argument("--fail-below", type=float, default=None,
                    metavar="RATIO",
                    help="exit 1 if any shared cell's speedup "
                         "is below RATIO")
    ap.add_argument("--higher-is-better", action="store_true",
                    help="the metric is a win, not a cost: compare "
                         "new/old instead of old/new so --fail-below "
                         "still gates regressions")
    args = ap.parse_args(argv)
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    diff = compare_reports(old, new, metric=args.metric,
                           higher_is_better=args.higher_is_better)
    print(render_diff(diff, threshold=args.fail_below))
    if not diff["cells"]:
        print("no comparable cells — nothing to gate on",
              file=sys.stderr)
        return 1
    if args.fail_below is not None:
        bad = [c for c in diff["cells"]
               if c["speedup"] < args.fail_below]
        if bad:
            print(f"FAIL: {len(bad)} cell(s) regressed below "
                  f"{args.fail_below}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised by subprocess
    sys.exit(main())
