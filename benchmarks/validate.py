"""Validate benchmark JSON reports against ``benchmarks/schema.json``.

Keeps ``BENCH_*.json`` machine-readable: CI runs this after every
``benchmarks.run --json`` smoke so a refactor can't silently change the
report shape that downstream trajectory tooling parses.

    PYTHONPATH=src python -m benchmarks.validate BENCH_pushpull.json

Uses ``jsonschema`` when installed; otherwise falls back to a built-in
validator covering the subset of draft-07 the schema uses (type,
required, properties, additionalProperties, items, enum, minimum,
exclusiveMinimum, maximum, $ref).
Rows named ``pushpull_*`` additionally have their ``derived`` payload
checked against ``definitions/pushpull_cell``, rows named ``service_*``
against ``definitions/service_cell``, rows named ``kernel_*`` against
``definitions/kernel_cell``, and rows named ``scaling_*`` against
``definitions/scaling_cell`` — the conventions the schema documents.
"""

from __future__ import annotations

import json
import os
import sys

SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "schema.json")

_TYPES = {
    "object": dict, "array": list, "string": str, "boolean": bool,
    "number": (int, float), "integer": int, "null": type(None),
}


def _check(instance, schema: dict, defs: dict, path: str = "$") -> None:
    """Minimal draft-07 subset validator; raises ValueError on mismatch."""
    if "$ref" in schema:
        _check(instance, defs[schema["$ref"].rsplit("/", 1)[-1]], defs,
               path)
        return
    t = schema.get("type")
    if t is not None:
        ok = isinstance(instance, _TYPES[t])
        if t in ("number", "integer") and isinstance(instance, bool):
            ok = False
        if not ok:
            raise ValueError(f"{path}: expected {t}, "
                             f"got {type(instance).__name__}")
    if "enum" in schema and instance not in schema["enum"]:
        raise ValueError(f"{path}: {instance!r} not in {schema['enum']}")
    if isinstance(instance, (int, float)) and not isinstance(instance,
                                                             bool):
        if "minimum" in schema and instance < schema["minimum"]:
            raise ValueError(f"{path}: {instance} < minimum "
                             f"{schema['minimum']}")
        if "exclusiveMinimum" in schema \
                and instance <= schema["exclusiveMinimum"]:
            raise ValueError(f"{path}: {instance} <= exclusiveMinimum "
                             f"{schema['exclusiveMinimum']}")
        if "maximum" in schema and instance > schema["maximum"]:
            raise ValueError(f"{path}: {instance} > maximum "
                             f"{schema['maximum']}")
    if isinstance(instance, dict):
        for req in schema.get("required", ()):
            if req not in instance:
                raise ValueError(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        for k, sub in props.items():
            if k in instance:
                _check(instance[k], sub, defs, f"{path}.{k}")
        extra = schema.get("additionalProperties")
        if isinstance(extra, dict):
            for k, v in instance.items():
                if k not in props:
                    _check(v, extra, defs, f"{path}.{k}")
    if isinstance(instance, list) and "items" in schema:
        for i, v in enumerate(instance):
            _check(v, schema["items"], defs, f"{path}[{i}]")


def load_schema() -> dict:
    with open(SCHEMA_PATH) as f:
        return json.load(f)


def validate_report(report: dict) -> bool:
    """Raise (jsonschema.ValidationError or ValueError) on an invalid
    report; return True when it conforms."""
    schema = load_schema()
    defs = schema.get("definitions", {})
    try:
        import jsonschema
        jsonschema.validate(report, schema)
    except ImportError:
        _check(report, schema, defs)
    # schema-documented conventions: pushpull_*, service_*, and
    # kernel_* rows carry structured cells
    for row in report.get("rows", ()):
        if row.get("name", "").startswith("pushpull_"):
            _check(row["derived"], defs["pushpull_cell"], defs,
                   f"$.rows[{row['name']}].derived")
        elif row.get("name", "").startswith("service_"):
            _check(row["derived"], defs["service_cell"], defs,
                   f"$.rows[{row['name']}].derived")
        elif row.get("name", "").startswith("kernel_"):
            _check(row["derived"], defs["kernel_cell"], defs,
                   f"$.rows[{row['name']}].derived")
        elif row.get("name", "").startswith("scaling_"):
            _check(row["derived"], defs["scaling_cell"], defs,
                   f"$.rows[{row['name']}].derived")
    return True


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.validate REPORT.json "
              "[REPORT.json ...]", file=sys.stderr)
        return 2
    for path in argv:
        with open(path) as f:
            report = json.load(f)
        validate_report(report)
        print(f"{path}: ok ({len(report['rows'])} rows, "
              f"{len(report['failures'])} failures)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
