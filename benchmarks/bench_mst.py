"""Fig 4: Boruvka MST push vs pull (FM phase dominates; pull avoids the
cross-component combining writes)."""

from __future__ import annotations

from repro.core.algorithms import boruvka_mst

from .common import emit, graph, timeit


def run():
    for gname in ("orc", "rca"):
        g = graph(gname, weighted=True)
        t_push = timeit(lambda: boruvka_mst(g, "push"), iters=2)
        t_pull = timeit(lambda: boruvka_mst(g, "pull"), iters=2)
        r = boruvka_mst(g, "pull")
        emit(f"mst_push_{gname}", t_push, f"rounds={int(r.rounds)}")
        emit(f"mst_pull_{gname}", t_pull,
             f"pull/push={t_pull/t_push:.2f};weight={float(r.weight):.0f}")


if __name__ == "__main__":
    run()
