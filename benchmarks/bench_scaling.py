"""Scaling sweep: the sharded engine at 1/2/4/8 shards (paper §6).

One fixed graph, one subprocess faking 8 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), sweeping the
shard count over device subsets. Per (algorithm × policy × shards) cell:
wall clock of a full ``api.solve(backend=ShardedBackend)`` run, total
inter-device wire bytes (the adaptive accounting the backend charges to
``Cost.collective_bytes``), and a correctness cross-check against the
single-device dense run. A compressed cell (error-feedback top-k on the
push accumulator) rides the same sweep.

The paper's DM claim shows up directly in the rows: BFS's frontier-
sparse push moves fewer bytes than its all_gather pull, while dense-
frontier PageRank pushes move more — the asymmetry ``AutoSwitch`` now
prices via ``StepStats.push/pull_wire_bytes``.

Rows are named ``scaling_*`` and carry a ``scaling_cell`` derived
payload (benchmarks/schema.json); ``benchmarks.validate`` enforces it.
"""

from __future__ import annotations

import os
import subprocess
import sys

from . import common
from .common import emit

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro import api
from repro.dist.compression import CompressionConfig
from repro.graphs import standin
from repro.shard import ShardedBackend

SCALE = %(scale)r
ITERS = %(iters)d
g = standin("orc", scale=SCALE, weighted=True)

CASES = [
    ("pagerank", dict(iters=20), "push", None),
    ("pagerank", dict(iters=20), "pull", None),
    ("pagerank", dict(iters=20), "push",
     CompressionConfig(kind="topk", topk_frac=0.05)),
    ("bfs", dict(root=0), "push", None),
    ("bfs", dict(root=0), "pull", None),
    ("bfs", dict(root=0), "auto", None),
]

refs = {}
for algo, kw, pol, _ in CASES:
    if (algo, pol) not in refs:
        refs[(algo, pol)] = api.solve(g, algo, policy=pol, **kw)

def states_match(algo, ref, got, compressed):
    if algo == "bfs":
        return bool(jnp.all(ref.state["dist"] == got.state["dist"]))
    tol = 5e-2 if compressed else 1e-5
    return bool(jnp.allclose(ref.state, got.state, rtol=tol, atol=tol))

for P in (1, 2, 4, 8):
    mesh = Mesh(np.array(jax.devices()[:P]).reshape(P, 1),
                ("data", "model"))
    plain = ShardedBackend.prepare(g, mesh=mesh)
    for algo, kw, pol, cfg in CASES:
        backend = (plain if cfg is None else
                   ShardedBackend.prepare(g, mesh=mesh, compression=cfg))
        run = lambda: api.solve(g, algo, policy=pol, backend=backend, **kw)
        r = run()
        jax.block_until_ready(r.state)
        ts = []
        for _ in range(ITERS):
            t0 = time.perf_counter()
            jax.block_until_ready(run().state)
            ts.append(time.perf_counter() - t0)
        us = sorted(ts)[len(ts) // 2] * 1e6
        comp = "none" if cfg is None else cfg.kind
        cell = {
            "algorithm": algo, "graph": "orc", "n": g.n, "m": g.m,
            "policy": pol, "backend": "shard", "shards": P,
            "compression": comp, "wall_us": round(us, 1),
            "collective_bytes": int(r.cost.collective_bytes),
            "steps": int(r.steps), "push_steps": int(r.push_steps),
            "converged": bool(r.converged),
            "weighted_total": float(r.cost.weighted_total()),
            "cut_edges": backend.cut_edges,
            "match": states_match(algo, refs[(algo, pol)], r,
                                  cfg is not None),
        }
        suffix = "" if cfg is None else "_" + comp
        print("ROW\t" + "scaling_" + algo + "_" + pol + suffix
              + "_P" + str(P) + "\t" + ("%%.1f" %% us) + "\t"
              + json.dumps(cell), flush=True)
"""


def run():
    scale = 1.0 / 1024 if common.SMOKE else 1.0 / 256
    iters = 1 if common.SMOKE else 3
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-c", _SUB % {"scale": scale, "iters": iters}],
        capture_output=True, text=True, timeout=1200, env=env, cwd=root)
    for line in r.stdout.splitlines():
        if line.startswith("ROW\t"):
            _, name, us, derived = line.split("\t", 3)
            emit(name, float(us), derived)
    if r.returncode != 0:
        print(r.stderr[-2000:])
        raise RuntimeError(f"scaling subprocess failed "
                           f"(exit {r.returncode})")


if __name__ == "__main__":
    run()
