"""Fig 1 + Table 6b: Boman coloring push vs pull + strategy iteration
counts (FE inflates; GS/GrS/CR restore — the paper's Table 6b shape)."""

from __future__ import annotations

import jax

from repro.core.algorithms import (boman_coloring, conflict_removal_coloring,
                                   fe_coloring, validate_coloring)
from repro.core.strategies import greedy_tail_coloring

from .common import emit, graph, timeit


def run():
    key = jax.random.PRNGKey(0)
    iters_table = {}
    for gname in ("orc", "ljn", "am", "rca"):
        scale = 1.0 / 4096 if gname in ("orc", "ljn") else 1.0 / 1024
        g = graph(gname, scale=scale)
        t_push = timeit(lambda: boman_coloring(g, 16, 64, "push"), iters=2)
        t_pull = timeit(lambda: boman_coloring(g, 16, 64, "pull"), iters=2)
        emit(f"bgc_push_{gname}", t_push, "")
        emit(f"bgc_pull_{gname}", t_pull,
             f"pull/push={t_pull/t_push:.2f}")

        base = boman_coloring(g, 16, 64, "push")
        fe = fe_coloring(g, key, direction="push")
        gs = fe_coloring(g, key, use_gs=True)
        cr = conflict_removal_coloring(g, 16, 64)
        assert all(bool(validate_coloring(g, r.colors))
                   for r in (base, fe, gs, cr))
        iters_table[gname] = {
            "push": int(base.iterations), "fe": int(fe.iterations),
            "fe+gs": int(gs.iterations), "cr": int(cr.iterations)}
        emit(f"bgc_iters_{gname}", 0.0,
             "push={push};fe={fe};fe+gs={fe+gs};cr={cr}".format(
                 **iters_table[gname]))
    return iters_table


if __name__ == "__main__":
    run()
