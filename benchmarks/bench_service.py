"""Service throughput suite — ``benchmarks.run --only service_throughput``.

Thin wrapper over :mod:`repro.service.bench`: the same (algorithm ×
policy × batch width) sweep, emitted through ``common.emit`` so the
rows land in ``benchmarks.run``'s JSON/markdown reports next to the
push/pull decision matrix. Rows are named ``service_*`` and validate
against ``benchmarks/schema.json``'s ``service_cell`` definition.

    PYTHONPATH=src python -m benchmarks.run --only service_throughput \
        [--smoke] [--json PATH] [--markdown PATH]
"""

from __future__ import annotations

import json

from . import common
from .common import emit


def run():
    from repro.service import bench as service_bench

    for name, us, payload in service_bench.sweep(smoke=common.SMOKE):
        emit(name, us, json.dumps(payload))


if __name__ == "__main__":
    run()
