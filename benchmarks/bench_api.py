"""API smoke benchmark: ``repro.api.solve`` across backends × policies.

Times the unified dispatch point on the stand-in power-law graph and
emits one JSON payload per (algorithm, policy, backend) cell via
``common.emit`` — the regression anchor for every future backend that
plugs into the registry. The phase-structured algorithms (sssp/bc/
coloring/mst/tc) run on a smaller stand-in, matching their dedicated
benches: their per-call work is superlinear in degree (TC) or carries
long sequential sub-phases (coloring), so the full-scale graph would
turn a smoke test into the benchmark itself.

    PYTHONPATH=src python -m benchmarks.run --only api_solve
"""

from __future__ import annotations

import json

from .common import emit, graph, timeit


def run():
    import jax
    from repro import api
    from repro.core import (DenseBackend, Direction, DistributedBackend,
                            EllBackend, Fixed, GenericSwitch)

    g_big = graph("orc", weighted=True)
    g_small = graph("orc", weighted=True, scale=1.0 / 4096)
    # TC's all-pairs intersection is O(m·d_ell²): use the sparse
    # road-network stand-in, like bench_tc
    g_sparse = graph("rca", weighted=True, scale=1.0 / 1024)
    backends = {"dense": DenseBackend(), "ell": EllBackend(),
                "dist1": DistributedBackend.prepare(g_big)}
    policies = [("push", Fixed(Direction.PUSH)),
                ("pull", Fixed(Direction.PULL)),
                ("gs", GenericSwitch())]
    cases = [("pagerank", {"iters": 10}, g_big),
             ("ppr", {"source": 0, "tol": 1e-4}, g_big),
             ("bfs", {"root": 0}, g_big),
             ("wcc", {}, g_big),
             ("pr_delta", {"tol": 1e-6}, g_big),
             ("sssp_delta", {"source": 0, "delta": 2.0}, g_small),
             ("betweenness", {"num_sources": 2}, g_small),
             ("coloring", {"num_parts": 8}, g_small),
             ("mst_boruvka", {}, g_small),
             ("triangle_count", {}, g_sparse)]
    dist_name = {"dense": "dense", "ell": "ell", "dist1": "distributed"}

    for alg, kw, g in cases:
        declared = api.get_spec(alg).backends
        for pname, policy in policies:
            for bname, backend in backends.items():
                if dist_name[bname] not in declared:
                    continue
                def fn():
                    r = api.solve(g, alg, policy=policy, backend=backend,
                                  **kw)
                    jax.block_until_ready(r.cost.reads)
                    return r
                us = timeit(fn)
                r = fn()
                payload = json.dumps({
                    "algorithm": alg, "policy": pname, "backend": bname,
                    "steps": int(r.steps), "push_steps": int(r.push_steps),
                    "epochs": int(r.epochs),
                    "reads": int(r.cost.reads),
                    "combining_writes": int(r.cost.atomics)
                                        + int(r.cost.locks),
                    "collective_bytes": int(r.cost.collective_bytes),
                })
                emit(f"api_{alg}_{pname}_{bname}", us, payload)


if __name__ == "__main__":
    run()
