"""API smoke benchmark: ``repro.api.solve`` across backends × policies.

Times the unified dispatch point on the stand-in power-law graph and
emits one JSON payload per (algorithm, policy, backend) cell via
``common.emit`` — the regression anchor for every future backend that
plugs into the registry.

    PYTHONPATH=src python -m benchmarks.run --only api_solve
"""

from __future__ import annotations

import json

from .common import emit, graph, timeit


def run():
    import jax
    from repro import api
    from repro.core import (DenseBackend, Direction, DistributedBackend,
                            EllBackend, Fixed, GenericSwitch)

    g = graph("orc", weighted=True)
    backends = [("dense", DenseBackend()), ("ell", EllBackend()),
                ("dist1", DistributedBackend.prepare(g))]
    policies = [("push", Fixed(Direction.PUSH)),
                ("pull", Fixed(Direction.PULL)),
                ("gs", GenericSwitch())]
    cases = [("pagerank", {"iters": 10}), ("bfs", {"root": 0}),
             ("wcc", {}), ("pr_delta", {"tol": 1e-6})]

    for alg, kw in cases:
        for pname, policy in policies:
            for bname, backend in backends:
                def fn():
                    r = api.solve(g, alg, policy=policy, backend=backend,
                                  **kw)
                    jax.block_until_ready(r.cost.reads)
                    return r
                us = timeit(fn)
                r = fn()
                payload = json.dumps({
                    "algorithm": alg, "policy": pname, "backend": bname,
                    "steps": int(r.steps), "push_steps": int(r.push_steps),
                    "reads": int(r.cost.reads),
                    "combining_writes": int(r.cost.atomics)
                                        + int(r.cost.locks),
                    "collective_bytes": int(r.cost.collective_bytes),
                })
                emit(f"api_{alg}_{pname}_{bname}", us, payload)


if __name__ == "__main__":
    run()
